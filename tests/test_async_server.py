"""The real-asynchrony test story (ISSUE 8; docs/architecture.md §11).

Because InProcTransport is a deterministic virtual-clock event loop, the
whole async stack is tier-1-testable:

* **equivalence** — the async server under a seeded latency table
  reproduces the simulated-clock ``fl_sim`` baseline: the selection stream
  and every client's credit-tick step stream are EXACT (replayed here from
  the shared key chain / integer tick grid), and the final accuracy is
  within tolerance (batch streams differ by construction).
* **fault classes** — straggler x10, 20% update drops (retry/backoff
  recovers them), duplicate+reorder (dedup holds), and a mid-run
  crash-and-rejoin all complete the run with graceful degradation instead
  of wedging a round.
* **determinism** — two runs of the same (actors, plan, seed) are
  bit-identical, transport counters included.
* **checkpointing** — the server's restartable state (flat buckets, rng
  key chain, PENDING admitted updates — LUQ codes + scales when
  quant_bits > 0) round-trips through ckpt.save/load_engine_checkpoint
  bit-exactly for bits in {0, 4} (the PR 7 checkpointing gap).

A SIGALRM per-test guard fails a wedged transport fast instead of hanging
the runner. The 2-client ProcTransport smoke is slow-marked here (CI runs
it tier-1 through the cluster CLI with artifact upload).
"""
import signal

import jax
import numpy as np
import pytest

from repro.comms import (BackoffPolicy, FaultPlan, InProcTransport,
                         symmetric_latency_table)
from repro.comms.transport import Actor
from repro.core import sampler
from repro.launch.cluster import _smoke_data, run_inproc, run_proc
from repro.launch.server import AsyncConfig, FavasAsyncServer

# -- per-test wedge guard ----------------------------------------------------

TEST_TIMEOUT_S = 300


@pytest.fixture(autouse=True)
def _per_test_timeout():
    """Fail fast instead of hanging the runner if a transport wedges."""
    if not hasattr(signal, "SIGALRM"):     # non-POSIX: no guard
        yield
        return

    def _alarm(signum, frame):
        raise RuntimeError(
            f"test exceeded the {TEST_TIMEOUT_S}s wedge guard")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


# -- shared deployment -------------------------------------------------------

N, S, K, ROUNDS = 6, 2, 5, 20
ROUND_DUR = 7.0          # fl_sim SERVER_WAIT + SERVER_INTERACT


def _cfg(rounds=ROUNDS, **kw):
    base = dict(n_clients=N, s_selected=S, K=K, eta=0.2, batch_size=16,
                rounds=rounds, round_dur=ROUND_DUR, seed=0)
    base.update(kw)
    return AsyncConfig(**base)


@pytest.fixture(scope="module")
def data():
    return _smoke_data(N, 0)


@pytest.fixture(scope="module")
def base_run(data):
    """One latency-injected deterministic run, shared by several tests."""
    return run_inproc(_cfg(), data, d_hidden=16,
                      plan=FaultPlan(latency=0.5), seed=0)


def _replay_selection(seed, n, s, rounds):
    """fl_sim's exact per-round key chain (fl_sim.py one_round)."""
    rkey = jax.random.PRNGKey(seed)
    out = []
    for _ in range(rounds):
        rkey, k_sel, _k_q = jax.random.split(rkey, 3)
        idx, _ = sampler.sample_selection_indices(k_sel, n, s)
        out.append(tuple(sorted(int(i) for i in np.asarray(idx))))
    return out


def _replay_credit(cfg, client_log, step_ticks, round_ticks, selected_rounds):
    """Host-integer replay of sampler.credit_steps + the q reset rule."""
    q = credit = 0
    for rec in client_log:
        credit += round_ticks
        avail = credit // step_ticks
        credit -= avail * step_ticks
        do = min(avail, cfg.K - q)
        if do != rec["do"]:
            return False
        q += do
        if rec["round"] in selected_rounds:
            q = 0
    return True


# -- the equivalence contract ------------------------------------------------

def test_async_matches_simulated_clock(data):
    """Headline: async server under a seeded latency table vs fl_sim —
    selection stream exact, credit streams exact, final accuracy within
    tolerance."""
    from repro.core.fl_sim import SimConfig, run_simulation
    rounds = 40
    cfg = _cfg(rounds=rounds)
    out = run_inproc(cfg, data, d_hidden=16, plan=FaultPlan(latency=0.5),
                     seed=0)
    res = out["server"]
    assert res["rounds"] == rounds
    assert res["stats"]["short_polls"] == 0       # every poll delivered

    # 1) selection stream: bit-identical to the fl_sim key chain
    assert res["selection"] == _replay_selection(0, N, S, rounds)

    # 2) credit streams: bit-identical to the integer tick clock
    step_time = cfg.step_times()
    step_ticks, round_ticks = sampler.time_ticks(step_time, ROUND_DUR)
    for i in range(N):
        sel_rounds = {r for r, sel in enumerate(res["selection"])
                      if i in sel}
        assert _replay_credit(cfg, out["client_logs"][f"client{i}"],
                              int(step_ticks[i]), round_ticks, sel_rounds), \
            f"client{i} credit stream diverged"

    # 3) alphas are the eq. 3 stochastic reweight of the pushed q's
    for rec in res["alpha"]:
        for a in rec.values():
            assert 1.0 <= a <= K

    # 4) convergence comparable to the simulated clock (batch streams
    #    differ by construction, so tolerance not bit-equality)
    sim = run_simulation(
        SimConfig(n_clients=N, s_selected=S, K=K, eta=0.2, batch_size=16,
                  total_time=rounds * ROUND_DUR,
                  eval_every=rounds * ROUND_DUR, seed=0),
        data, d_hidden=16)
    assert res["final_accuracy"] is not None
    assert abs(res["final_accuracy"] - sim["final_accuracy"]) <= 0.1


def test_deterministic_double_run(data, base_run):
    """Same (actors, plan, seed) -> bit-identical everything."""
    again = run_inproc(_cfg(), data, d_hidden=16,
                       plan=FaultPlan(latency=0.5), seed=0)
    a, b = base_run["server"], again["server"]
    assert a["selection"] == b["selection"]
    assert a["alpha"] == b["alpha"]
    assert a["staleness"] == b["staleness"]
    assert a["final_accuracy"] == b["final_accuracy"]
    assert base_run["transport"] == again["transport"]
    assert base_run["client_logs"] == again["client_logs"]


def test_base_run_bookkeeping(base_run):
    res = base_run["server"]
    assert res["rounds"] == ROUNDS
    assert res["stats"]["admitted"] == ROUNDS * S
    assert res["stats"]["resets"] == ROUNDS * S
    assert res["stats"]["byes"] == N
    assert len(res["staleness"]) == ROUNDS * S
    assert all(0 <= q <= K for q in res["staleness"])


# -- fault classes -----------------------------------------------------------

def test_straggler_degrades_gracefully(data):
    """A x10 straggler misses harvest windows but the run completes; the
    other clients keep the server moving."""
    out = run_inproc(_cfg(), data, d_hidden=16,
                     plan=FaultPlan(latency=0.5,
                                    straggler={"client0": 10.0}), seed=0)
    res = out["server"]
    assert res["rounds"] == ROUNDS
    assert res["stats"]["short_polls"] > 0        # the straggler missed polls
    assert res["stats"]["admitted"] < ROUNDS * S
    assert res["stats"]["admitted"] > 0
    assert res["final_accuracy"] is not None
    assert 0.0 <= res["final_accuracy"] <= 1.0
    # stale acks stopped the straggler's retries (no unbounded resend)
    assert out["client_stats"]["client0"]["gave_up"] == 0


def test_drops_recovered_by_retry(data):
    """20% update drops: the backoff retries recover every poll."""
    out = run_inproc(_cfg(), data, d_hidden=16,
                     plan=FaultPlan(latency=0.5, drop=0.2), seed=0)
    res = out["server"]
    assert out["transport"]["dropped"] > 0        # the fault actually fired
    assert res["rounds"] == ROUNDS
    assert res["stats"]["admitted"] == ROUNDS * S  # retries recovered all
    retries = sum(s["retries"] for s in out["client_stats"].values())
    assert retries > 0


def test_duplicates_and_reorder_deduped(data):
    """Duplicated / reordered update copies are admitted once each."""
    out = run_inproc(_cfg(), data, d_hidden=16,
                     plan=FaultPlan(latency=0.5, duplicate=0.5, reorder=0.3,
                                    reorder_delay=2.0), seed=0)
    res = out["server"]
    assert out["transport"]["duplicated"] > 0
    assert res["rounds"] == ROUNDS
    assert res["stats"]["admitted"] == ROUNDS * S  # dedup by (round, client)


def test_crash_and_rejoin(data):
    """A client crashes mid-run, is blackholed, rejoins via join/sync, and
    participates again; the run completes."""
    t0 = 3 * ROUND_DUR
    out = run_inproc(_cfg(), data, d_hidden=16,
                     plan=FaultPlan(latency=0.5,
                                    crash={"client1": (t0, t0 + 6 * ROUND_DUR)}),
                     seed=0)
    res = out["server"]
    assert res["rounds"] == ROUNDS
    assert out["transport"]["blackholed"] > 0
    assert res["stats"]["rejoins"] == 1
    assert out["client_stats"]["client1"]["rejoins"] == 1
    # the crashed client missed its in-window polls -> some short polls
    assert res["stats"]["short_polls"] > 0
    # but it pushed again after rejoining
    post = [rec for rec in out["client_logs"]["client1"]
            if rec["polled"]]
    assert len(post) > 0


def test_per_client_latency_table(data):
    """A seeded per-client latency table drives admission: slow links miss
    the harvest deadline, fast links always deliver."""
    table = symmetric_latency_table(
        [f"client{i}" for i in range(N)],
        [0.2] * (N - 1) + [ROUND_DUR])            # client5's link > window
    out = run_inproc(_cfg(), data, d_hidden=16,
                     plan=FaultPlan(latency_table=table), seed=0)
    res = out["server"]
    assert res["rounds"] == ROUNDS
    slow_sel = sum(1 for sel in res["selection"] if 5 in sel)
    admitted5 = sum(1 for rec in res["alpha"] if "client5" in rec)
    assert slow_sel > 0 and admitted5 == 0        # never made a harvest
    assert res["stats"]["admitted"] == ROUNDS * S - slow_sel


# -- transport unit behaviour ------------------------------------------------

class _Echo(Actor):
    def __init__(self, node_id):
        self.node_id = node_id
        self.seen = []

    def on_message(self, src, msg, api):
        self.seen.append((api.now(), msg["i"]))


class _Burst(Actor):
    node_id = "burst"

    def __init__(self, dst, n, kind="data"):
        self.dst, self.n, self.kind = dst, n, kind

    def on_start(self, api):
        for i in range(self.n):
            api.send(self.dst, {"kind": self.kind, "i": i})
        api.stop()


def test_inproc_fifo_per_pair():
    """Same-pair messages deliver in send order even at equal latency."""
    t = InProcTransport(FaultPlan(latency=1.0), seed=0)
    sink = _Echo("sink")
    t.add_actor(_Burst("sink", 50))
    t.add_actor(sink)
    t.run()
    assert [i for _, i in sink.seen] == list(range(50))


def test_inproc_reorder_overtakes():
    """reorder=1.0 exempts update-class messages from the FIFO clamp, so a
    later control message can overtake only when the fault says so."""
    t = InProcTransport(FaultPlan(latency=1.0, reorder=1.0,
                                  reorder_delay=5.0), seed=0)
    sink = _Echo("sink")
    t.add_actor(_Burst("sink", 1, kind="update"))   # delayed by reorder
    t.add_actor(sink)
    t.run()
    assert sink.seen and sink.seen[0][0] == pytest.approx(6.0)


def test_inproc_max_events_guard():
    """A ping-pong protocol bug raises instead of wedging."""
    class _Ping(Actor):
        def __init__(self, me, peer):
            self.node_id, self.peer = me, peer

        def on_start(self, api):
            if self.node_id == "a":
                api.send(self.peer, {"kind": "ping"})

        def on_message(self, src, msg, api):
            api.send(src, {"kind": "ping"})

    t = InProcTransport(FaultPlan(latency=0.1), seed=0)
    t.add_actor(_Ping("a", "b"))
    t.add_actor(_Ping("b", "a"))
    with pytest.raises(RuntimeError, match="wedged|exceeded"):
        t.run(max_events=500)


def test_fault_decide_draw_count_invariant():
    """decide() consumes the same rng draws whatever the outcome, so fault
    probabilities don't perturb the latency stream of later messages."""
    for plan in (FaultPlan(jitter=0.5),
                 FaultPlan(jitter=0.5, drop=1.0),
                 FaultPlan(jitter=0.5, drop=0.0, duplicate=1.0, reorder=1.0,
                           reorder_delay=1.0)):
        rng = np.random.default_rng(7)
        plan.decide("a", "b", "update", rng)
        follow = rng.uniform()
        rng2 = np.random.default_rng(7)
        FaultPlan(jitter=0.5).decide("a", "b", "update", rng2)
        assert follow == rng2.uniform()


def test_backoff_policy():
    p = BackoffPolicy(base=0.5, factor=2.0, max_delay=3.0, max_attempts=4)
    assert [p.delay(k) for k in range(4)] == [0.5, 1.0, 2.0, 3.0]
    assert not p.exhausted(3)
    assert p.exhausted(4)
    with pytest.raises(ValueError):
        BackoffPolicy(base=0.0)


# -- checkpointing: pending quantized updates (the PR 7 gap) -----------------

class _FakeAPI:
    """Minimal TransportAPI capturing sends, for driving the server's
    handlers synchronously."""
    node_id = "server"

    def __init__(self):
        self.sent = []
        self._t = 0.0

    def now(self):
        return self._t

    def send(self, dst, msg):
        self.sent.append((dst, msg))

    def set_timer(self, name, delay):
        pass

    def cancel_timer(self, name):
        pass

    def stop(self):
        pass


def _server_with_pending(bits: int):
    """Drive a server to mid-round with one admitted (pending) update."""
    from repro.models.classifier import mlp_init
    params0 = mlp_init(jax.random.PRNGKey(0), 8, 8, 3)
    cfg = AsyncConfig(n_clients=4, s_selected=2, K=4, rounds=4,
                      quant_bits=bits, seed=0)
    srv = FavasAsyncServer(cfg, params0)
    api = _FakeAPI()
    srv.on_start(api)
    srv.on_timer("barrier", api)
    srv.on_timer("round", api)          # opens round 0, draws k_sel/k_q
    polled = srv._polled[0]
    rng = np.random.default_rng(3)
    bufs = [np.asarray(b) + rng.standard_normal(b.shape).astype(np.float32)
            for b in srv._server_payload()]
    srv.on_message(polled, {"kind": "update", "round": 0, "q": 3,
                            "params": bufs}, api)
    assert len(srv.pending) == 1        # round still open (s=2)
    return srv


@pytest.mark.parametrize("bits", [0, 4])
def test_server_checkpoint_roundtrip(bits, tmp_path):
    """Codes + scales + key chain of the pending admitted update survive
    save/load bit-exactly, for raw (bits=0) and LUQ (bits=4) admission."""
    srv = _server_with_pending(bits)
    state = srv.checkpoint_state()
    if bits > 0:
        ent = next(iter(state["pending"].values()))
        assert ent["codes0"].dtype == np.uint8    # truly held quantized
        assert ent["scale0"].dtype == np.float32
    path = srv.save(str(tmp_path), step=0)

    other = _server_with_pending(bits)            # identical protocol point
    # perturb, then restore: load must win, bit-exactly
    other.rkey = jax.random.PRNGKey(99)
    other.srv_f = tuple(b + 1.0 for b in other.srv_f)
    other.load(path)
    back = other.checkpoint_state()
    assert np.array_equal(np.asarray(back["rkey"]), np.asarray(state["rkey"]))
    for a, b in zip(back["server"], state["server"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for c, ent in state["pending"].items():
        for k, v in ent.items():
            got = back["pending"][c][k]
            assert np.asarray(got).dtype == np.asarray(v).dtype
            np.testing.assert_array_equal(np.asarray(got), np.asarray(v))


def test_quantized_deployment_runs(data):
    """End-to-end with quant_bits=4: pending updates ride as codes and the
    run still completes/aggregates."""
    out = run_inproc(_cfg(rounds=6, quant_bits=4), data, d_hidden=16,
                     plan=FaultPlan(latency=0.5), seed=0)
    res = out["server"]
    assert res["rounds"] == 6
    assert res["stats"]["admitted"] == 6 * S
    assert res["final_accuracy"] is not None


# -- prefetcher close hardening ----------------------------------------------

def test_prefetcher_close_joins_and_reports():
    import threading
    from repro.data.pipeline import BatchPrefetcher
    before = threading.active_count()
    pf = BatchPrefetcher(lambda i: np.zeros((4,)), n_steps=100,
                         to_device=False)
    pf.get()
    assert pf.close() is True
    assert not pf._thread.is_alive()
    assert threading.active_count() == before


def test_prefetcher_close_deadline_warns_on_slow_producer():
    import time as _time
    from repro.data.pipeline import BatchPrefetcher

    def slow(i):
        _time.sleep(1.5)                # longer than the close deadline
        return np.zeros((2,))

    pf = BatchPrefetcher(slow, n_steps=10, to_device=False)
    t0 = _time.monotonic()
    with pytest.warns(RuntimeWarning, match="still alive"):
        ok = pf.close(timeout=0.3)
    assert ok is False
    assert _time.monotonic() - t0 < 1.0   # the deadline is wall-clock
    pf._thread.join(timeout=5.0)          # producer exits once sleep ends
    assert not pf._thread.is_alive()


# -- Gumbel top-s selection statistics (satellite) ---------------------------

def _chi2_critical(dof: int, z: float = 3.0902) -> float:
    """Wilson-Hilferty approximation of the chi-square quantile (p=0.999
    for the default z) — scipy is not available in this environment."""
    return dof * (1.0 - 2.0 / (9.0 * dof)
                  + z * np.sqrt(2.0 / (9.0 * dof))) ** 3


@pytest.mark.slow
@pytest.mark.parametrize("n,s", [(12, 4), (9, 1)])
def test_selection_inclusion_frequencies_chi2(n, s):
    """Gumbel top-s inclusion frequencies match the uniform s/n inclusion
    probability: chi-square GOF over per-client selection counts across
    many seeded rounds. (Within-round draws are without replacement, which
    only shrinks the count variance vs the multinomial null — the test is
    conservative, catching bias regressions without false alarms.)"""
    rounds = 4000
    keys = jax.random.split(jax.random.PRNGKey(123), rounds)
    idx, mask = jax.vmap(
        lambda k: sampler.sample_selection_indices(k, n, s))(keys)
    idx = np.asarray(idx)
    mask = np.asarray(mask)
    # every round selects exactly s distinct clients
    assert mask.sum(axis=1).min() == s and mask.sum(axis=1).max() == s
    assert all(len(set(row)) == s for row in idx)
    counts = mask.sum(axis=0)
    expected = rounds * s / n
    stat = float(((counts - expected) ** 2 / expected).sum())
    assert stat < _chi2_critical(n - 1), \
        f"chi2={stat:.1f} exceeds the p=0.999 critical value"


# -- the real multi-process transport ----------------------------------------

@pytest.mark.slow
def test_proc_transport_smoke(data):
    """2 real client processes, 20 rounds under injected latency, clean
    teardown (CI runs the same scenario tier-1 via the cluster CLI)."""
    cfg = AsyncConfig(n_clients=2, s_selected=1, K=4, batch_size=16,
                      rounds=20, round_dur=0.4,
                      fast_step_time=0.1, slow_step_time=0.2, seed=0)
    x, y, xt, yt, _ = data
    from repro.data.partition import partition_iid
    parts = partition_iid(len(y), 2, seed=0)
    out = run_proc(cfg, (x, y, xt, yt, parts), d_hidden=16,
                   plan=FaultPlan(latency=0.02), seed=0, timeout=90.0)
    res = out["server"]
    assert out["clean"], f"child exit codes: {out['exitcodes']}"
    assert res["rounds"] == 20
    assert res["stats"]["admitted"] > 0
    assert res["final_accuracy"] is not None
    # the deterministic halves hold on the wall clock too
    assert res["selection"] == _replay_selection(0, 2, 1, 20)
