"""Hypothesis fuzz of the FlatSpec stacked round-trip: flatten_stacked ->
unflatten_stacked must be bit-exact for ARBITRARY client counts, client
tiles, and mixed-dtype leaf layouts (client-axis padding included). The
deterministic fixed cases live in tests/test_tiled_kernel.py; this module
explores the space.
"""
import pytest

# hypothesis is an optional test dependency; without the guard the whole
# tier-1 suite dies at collection (pytest stops on a collection error)
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_tiled_kernel import _LEAF_DTYPES, check_stacked_roundtrip_bit_exact


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 23),
    client_tile=st.sampled_from([4, 8]),
    seed=st.integers(0, 2 ** 16),
    layout=st.lists(
        st.tuples(st.lists(st.integers(1, 5), min_size=0, max_size=3),
                  st.integers(0, len(_LEAF_DTYPES) - 1)),
        min_size=1, max_size=5),
)
def test_flat_spec_stacked_roundtrip_bit_exact(n, client_tile, seed, layout):
    check_stacked_roundtrip_bit_exact(n, client_tile, seed,
                                      [(tuple(s), d) for s, d in layout])
