"""Superstep (multi-round scan) + host-pipeline tests
(docs/architecture.md §7):

* **bit-exact parity** — ``RoundEngine.run`` over a T-round chunk equals T
  sequential ``engine.step`` calls, array-for-array, across
  T in {7, 257} x {fp32, bf16} x {plain, quant_bits=4} (the mesh
  variants live in tests/test_sharded_engine.py, which the CI ``sharded``
  job runs on 8 forced devices). Parity is exact because ``engine_round``
  derives every draw from the carried ``state.key`` — the scanned RNG
  stream IS the sequential stream.
* **donation** — the superstep donates the previous state's buffers (they
  are deleted after the call) and repeated chunks do not grow the live-
  buffer population.
* **dispatch-count guard** — one chunk = ONE dispatch into the jitted
  superstep (``RoundEngine.dispatch_count``), and ``engine_round`` is not
  re-traced on subsequent same-shape chunks: <= 2 XLA executions per
  32-round chunk (the round itself + at most one metrics fetch), never a
  per-round dispatch loop.
* **prefetcher contract** — ``data.pipeline.BatchPrefetcher`` preserves
  the seeded rng stream exactly, surfaces producer errors at ``get()``,
  bounds its lookahead, and stops cleanly.
* on-device simulator bookkeeping (``sampler.credit_steps``,
  ``sampler.sample_selection_indices``) matches the host arithmetic it
  replaced, and ``fl_sim._window_schedule`` replicates the per-round
  loop's record points.
"""
import functools
import queue

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import round_engine, sampler
from repro.core.favas import (FavasConfig, client_lambdas, favas_init,
                              favas_multi_round, favas_round)
from repro.data.pipeline import BatchPrefetcher


def _params(dtype):
    """Tiny mixed-bucket pytree (one leaf stays f32 when dtype is bf16)."""
    w = jnp.asarray(np.linspace(-1.0, 1.0, 48).reshape(8, 6), dtype)
    b = jnp.asarray(np.linspace(0.5, 1.5, 5), jnp.float32)
    return {"w": w, "b": b}


def _loss(p, batch):
    return sum(jnp.mean((l.astype(jnp.float32) - batch["t"]) ** 2)
               for l in jax.tree_util.tree_leaves(p))


def _batches(fcfg, T, seed=0):
    vals = np.linspace(0.0, 1.0, T * fcfg.n_clients * fcfg.R) + 0.01 * seed
    return {"t": jnp.asarray(vals.reshape(T, fcfg.n_clients, fcfg.R),
                             jnp.float32)}


def _engine(dtype, quant_bits=0, n=5):
    params = _params(dtype)
    fcfg = FavasConfig(n_clients=n, s_selected=2, local_steps=2, eta=0.1,
                       quant_bits=quant_bits)
    eng = round_engine.RoundEngine(
        params, fcfg, _loss, lambdas=jnp.asarray(client_lambdas(fcfg)))
    return eng, fcfg, params


def _assert_states_equal(a, b):
    for x, y in zip(a.server + a.clients + a.inits,
                    b.server + b.clients + b.inits):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    np.testing.assert_array_equal(np.asarray(a.counters), np.asarray(b.counters))
    np.testing.assert_array_equal(np.asarray(a.stale), np.asarray(b.stale))
    np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))
    assert int(a.t) == int(b.t)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
@pytest.mark.parametrize("quant", [0, 4], ids=["plain", "quant4"])
@pytest.mark.parametrize("T", [7, 257])
def test_superstep_bit_exact_vs_sequential(T, dtype, quant):
    """run(n_rounds=T) == T sequential step() calls, bit-for-bit, including
    the (T,)-stacked metrics stream."""
    eng, fcfg, params = _engine(dtype, quant_bits=quant)
    key = jax.random.PRNGKey(3)
    s_seq = eng.init_state(params, key)
    s_sup = eng.init_state(params, key)
    batches = _batches(fcfg, T)
    seq_metrics = []
    for t in range(T):
        s_seq, m = eng.step(
            s_seq, jax.tree_util.tree_map(lambda x: x[t], batches))
        seq_metrics.append(m)
    s_sup, ms = eng.run(s_sup, batches, n_rounds=T)
    _assert_states_equal(s_seq, s_sup)
    for k in ("loss", "mean_steps", "selected", "stale_rounds"):
        np.testing.assert_array_equal(
            np.asarray(ms[k]),
            np.asarray([float(m[k]) for m in seq_metrics], np.float32),
            err_msg=k)


def test_favas_multi_round_matches_sequential_pytree_api():
    """The pytree-API wrapper scans identically to sequential favas_round
    (what launch/steps.py's rounds_per_step > 1 train step runs)."""
    params = _params(jnp.float32)
    fcfg = FavasConfig(n_clients=4, s_selected=2, local_steps=2, eta=0.1)
    lambdas = jnp.asarray(client_lambdas(fcfg))
    key = jax.random.PRNGKey(0)
    st1 = favas_init(params, fcfg, key)
    st2 = favas_init(params, fcfg, key)
    T = 5
    batches = _batches(fcfg, T)
    step = jax.jit(functools.partial(favas_round, cfg=fcfg, loss_fn=_loss,
                                     lambdas=lambdas))
    multi = jax.jit(functools.partial(favas_multi_round, cfg=fcfg,
                                      loss_fn=_loss, lambdas=lambdas))
    for t in range(T):
        st1, _ = step(st1, jax.tree_util.tree_map(lambda x: x[t], batches))
    st2, ms = multi(st2, batches)
    for a, b in zip(jax.tree_util.tree_leaves(st1),
                    jax.tree_util.tree_leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert ms["loss"].shape == (T,)


def test_superstep_donates_and_no_live_buffer_growth():
    """The superstep donates the previous chunk's buffers (deleted after
    the call) and chunk-to-chunk steady state allocates nothing new."""
    eng, fcfg, params = _engine(jnp.float32)
    state = eng.init_state(params, jax.random.PRNGKey(0))
    batches = _batches(fcfg, 8)
    prev = state
    state, m = eng.run(state, batches)
    del m
    assert prev.server[0].is_deleted(), "superstep must donate the state"
    jax.block_until_ready(state.server)
    counts = []
    for i in range(4):
        state, m = eng.run(state, batches)
        del m
        jax.block_until_ready(state.server)
        counts.append(len(jax.live_arrays()))
    assert max(counts) == min(counts), (
        f"live-buffer population grew across chunks: {counts}")


def test_superstep_dispatch_count_guard():
    """<= 2 XLA executions per 32-round chunk. Measured at the jitted-
    callable boundary (every invocation of a compiled pjit callable is an
    XLA execution): run() must enter a compiled callable exactly ONCE per
    chunk — never a per-round loop over the single-round executable — the
    round body must not re-trace once the chunk shape is compiled, and the
    32-round loop itself must live ON-DEVICE (a `while` op in the compiled
    superstep HLO), not in python."""
    eng, fcfg, params = _engine(jnp.float32)
    state = eng.init_state(params, jax.random.PRNGKey(1))
    batches = _batches(fcfg, 32)
    calls = {"n": 0}
    traces = {"n": 0}
    orig_round_fn = round_engine.engine_round
    orig_multi, orig_round = eng._multi, eng._round

    def counting_trace(*a, **kw):
        traces["n"] += 1
        return orig_round_fn(*a, **kw)

    def wrap(fn):
        def inner(*a, **kw):
            calls["n"] += 1
            return fn(*a, **kw)
        return inner

    round_engine.engine_round = counting_trace
    eng._multi, eng._round = wrap(orig_multi), wrap(orig_round)
    try:
        state, m = eng.run(state, batches, n_rounds=32)      # compile + run
        del m
        assert calls["n"] <= 2, (
            f"{calls['n']} compiled-callable entries for one 32-round chunk")
        first_traces = traces["n"]
        assert first_traces >= 1                             # traced once...
        calls["n"] = 0
        state, m = eng.run(state, batches, n_rounds=32)      # cache hit
        del m
        assert calls["n"] == 1, (
            "a 32-round chunk must be ONE compiled dispatch, not a "
            "per-round loop")
        assert traces["n"] == first_traces, "round body re-traced on chunk 2"
        assert eng.dispatch_count == 2
    finally:
        round_engine.engine_round = orig_round_fn
        eng._multi, eng._round = orig_multi, orig_round
    # the scan is on-device: the compiled superstep contains an XLA while
    # loop (a python-loop regression would compile 32 unrolled/looped host
    # dispatches instead and fail the counter above)
    hlo = orig_multi.lower(state, batches).compile().as_text()
    assert "while" in hlo, "superstep HLO has no on-device loop"
    # the sequential driver really does dispatch per round (counter sanity)
    eng.dispatch_count = 0
    for t in range(4):
        state, m = eng.step(
            state, jax.tree_util.tree_map(lambda x: x[t], batches))
    assert eng.dispatch_count == 4


def test_superstep_rejects_mismatched_n_rounds():
    eng, fcfg, params = _engine(jnp.float32)
    state = eng.init_state(params, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="n_rounds"):
        eng.run(state, _batches(fcfg, 4), n_rounds=8)


# ---------------------------------------------------------------------------
# BatchPrefetcher contract
# ---------------------------------------------------------------------------

def test_prefetcher_preserves_rng_stream():
    """Single producer thread in index order => byte-identical to the
    synchronous loop for a seeded generator."""
    sync_rng = np.random.default_rng(0)
    want = [sync_rng.integers(0, 1000, (4,)) for _ in range(6)]
    pf_rng = np.random.default_rng(0)
    with BatchPrefetcher(lambda i: pf_rng.integers(0, 1000, (4,)),
                         n_steps=6, to_device=False) as pf:
        got = list(pf)
    assert len(got) == 6
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)


def test_prefetcher_exhausts_and_stops():
    with BatchPrefetcher(lambda i: i, n_steps=3, to_device=False) as pf:
        assert [pf.get() for _ in range(3)] == [0, 1, 2]
        with pytest.raises(StopIteration):
            pf.get()


def test_prefetcher_propagates_producer_errors():
    def boom(i):
        if i == 2:
            raise RuntimeError("generator exploded")
        return i

    with BatchPrefetcher(boom, n_steps=5, to_device=False) as pf:
        assert pf.get() == 0 and pf.get() == 1
        with pytest.raises(RuntimeError, match="generator exploded"):
            pf.get()


def test_prefetcher_bounded_lookahead():
    """With depth=2 the producer never runs more than depth+1 chunks ahead
    of the consumer (one may be mid-build when the queue is full)."""
    import time
    produced = []

    def make(i):
        produced.append(i)
        return i

    with BatchPrefetcher(make, n_steps=10, depth=2, to_device=False) as pf:
        time.sleep(0.3)                      # let the producer run ahead
        assert len(produced) <= 3
        assert pf.get() == 0


def test_prefetcher_device_put_path():
    with BatchPrefetcher(lambda i: {"x": np.full((2, 2), i, np.float32)},
                         n_steps=2) as pf:
        b = pf.get()
        assert isinstance(b["x"], jax.Array)
        assert float(b["x"][0, 0]) == 0.0


def test_prefetcher_close_unblocks_full_queue_producer():
    """close() while the producer is parked on a FULL queue must return
    promptly (no deadlock) and leave the thread dead — even though the
    consumer never called get()."""
    import time
    with BatchPrefetcher(lambda i: np.zeros(4), n_steps=1000, depth=1,
                         to_device=False) as pf:
        time.sleep(0.3)                     # queue fills, producer blocks
        t0 = time.perf_counter()
        pf.close()
        took = time.perf_counter() - t0
    assert took < 5.0, f"close() hung {took:.1f}s on a blocked producer"
    assert not pf._thread.is_alive()


def test_prefetcher_exit_propagates_pending_error():
    """A producer error the consumer never reached via get() must re-raise
    from __exit__ — early consumer exit cannot swallow failures."""
    import time

    def boom(i):
        if i == 1:
            raise RuntimeError("late generator explosion")
        return i

    with pytest.raises(RuntimeError, match="late generator explosion"):
        with BatchPrefetcher(boom, n_steps=5, to_device=False) as pf:
            assert pf.get() == 0            # never consumes the error
            time.sleep(0.3)                 # let the producer hit i == 1
    # ... but an in-flight body exception wins over the pending error
    with pytest.raises(KeyError, match="body"):
        with BatchPrefetcher(boom, n_steps=5, to_device=False) as pf2:
            time.sleep(0.3)
            raise KeyError("body")


# ---------------------------------------------------------------------------
# Host-path batch generation: vectorized gathers + stream versioning
# ---------------------------------------------------------------------------

def test_lm_round_batch_vectorized_matches_seed_stream():
    """The vectorized lm_round_batch (default stream="v1") is value- and
    stream-identical to the seed's triple Python loop: same rng.integers
    call per client, gather moved to one numpy indexing expression."""
    from repro.data import make_lm_corpus
    from repro.data.pipeline import lm_round_batch
    tokens, domains = make_lm_corpus(32, 20_000, n_domains=3, seed=0)
    n, R, Bv, S = 7, 3, 2, 5

    def seed_loop(rng):
        n_domains = int(domains.max()) + 1
        out = np.empty((n, R, Bv, S), np.int32)
        dom_index = [np.where(domains == d)[0] for d in range(n_domains)]
        for i in range(n):
            pool = dom_index[i % n_domains]
            lo, hi = pool.min(), pool.max() - S - 1
            starts = rng.integers(lo, max(hi, lo + 1), (R, Bv))
            for k in range(R):
                for b in range(Bv):
                    s = int(starts[k, b])
                    out[i, k, b] = tokens[s:s + S]
        return out

    ref_rng = np.random.default_rng(5)
    want = seed_loop(ref_rng)
    rng = np.random.default_rng(5)
    got = lm_round_batch(tokens, domains, n, R, Bv, S, rng)
    np.testing.assert_array_equal(got, want)
    # the generator advanced identically: the NEXT draws agree too
    np.testing.assert_array_equal(rng.integers(0, 100, 8),
                                  ref_rng.integers(0, 100, 8))


def test_lm_round_batch_v2_stream_is_versioned():
    from repro.data import make_lm_corpus
    from repro.data.pipeline import lm_round_batch, _lm_start_bounds
    tokens, domains = make_lm_corpus(32, 20_000, n_domains=3, seed=0)
    n, R, Bv, S = 5, 2, 3, 4
    a = lm_round_batch(tokens, domains, n, R, Bv, S,
                       np.random.default_rng(1), stream="v2")
    b = lm_round_batch(tokens, domains, n, R, Bv, S,
                       np.random.default_rng(1), stream="v2")
    np.testing.assert_array_equal(a, b)     # deterministic under the seed
    assert a.shape == (n, R, Bv, S) and a.dtype == np.int32
    with pytest.raises(ValueError, match="stream"):
        lm_round_batch(tokens, domains, n, R, Bv, S,
                       np.random.default_rng(1), stream="v3")
    lo, span = _lm_start_bounds(domains, n, S)
    assert lo.shape == (n,) and np.all(span >= 1)


def test_federated_batcher_v1_stream_unchanged_and_v2_valid():
    """v1 stays byte-identical to the seed loop (same generator calls);
    v2 is fully vectorized, deterministic, and only ever samples rows
    from the owning client's partition."""
    from repro.data.pipeline import FederatedBatcher
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (300, 4)).astype(np.float32)
    y = rng.integers(0, 5, 300).astype(np.int32)
    parts = [rng.choice(300, m, replace=False) for m in (3, 40, 11, 70)]

    def seed_round_batch(b, n_steps):
        n = len(b.parts)
        xs = np.empty((n, n_steps, b.B) + b.x.shape[1:], b.x.dtype)
        ys = np.empty((n, n_steps, b.B), b.y.dtype)
        for i in range(n):
            for k in range(n_steps):
                idx = b.parts[i]
                take = b.rng.choice(idx, b.B, replace=len(idx) < b.B)
                xs[i, k], ys[i, k] = b.x[take], b.y[take]
        return xs, ys

    ref = FederatedBatcher(x, y, parts, 8, seed=3)
    want = seed_round_batch(ref, 3)
    got = FederatedBatcher(x, y, parts, 8, seed=3).round_batch(3)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])

    v2 = FederatedBatcher(x, y, parts, 8, seed=3, stream="v2")
    xs2, ys2 = v2.round_batch(3)
    assert xs2.shape == want[0].shape and ys2.shape == want[1].shape
    xs2b, _ = FederatedBatcher(x, y, parts, 8, seed=3,
                               stream="v2").round_batch(3)
    np.testing.assert_array_equal(xs2, xs2b)
    # partition containment: reverse rows through x is ambiguous, so check
    # via y-label multisets per client instead of exact rows
    for i, p in enumerate(parts):
        allowed = set(y[p].tolist())
        assert set(ys2[i].ravel().tolist()) <= allowed
    with pytest.raises(ValueError, match="stream"):
        FederatedBatcher(x, y, parts, 8, stream="v9")


# ---------------------------------------------------------------------------
# On-device simulator bookkeeping primitives
# ---------------------------------------------------------------------------

def test_credit_steps_matches_host_arithmetic():
    """sampler.credit_steps (integer ticks) == the f64 numpy credit/step-
    time loop it replaced (fl_sim's App. C.2 clock), over several
    accumulating rounds at the paper's representable step times."""
    rng = np.random.default_rng(0)
    n, K, round_dur = 9, 5, 7.0
    step_time = rng.choice([2.0, 16.0], n)
    step_ticks, round_ticks = sampler.time_ticks(step_time, round_dur)
    q_np = np.zeros(n)
    credit_np = np.zeros(n)
    q_j = jnp.zeros((n,), jnp.float32)
    credit_j = jnp.zeros((n,), jnp.int32)
    st_j = jnp.asarray(step_ticks)
    for r in range(6):
        credit_np += round_dur
        avail = np.floor(credit_np / step_time)
        credit_np -= avail * step_time
        do_np = np.minimum(avail, K - q_np)
        do_j, credit_j = sampler.credit_steps(credit_j, st_j, q_j, K,
                                              round_ticks)
        np.testing.assert_array_equal(np.asarray(do_j), do_np)
        # tick credit is the host's float credit on the tick grid, exactly
        np.testing.assert_array_equal(
            np.asarray(credit_j),
            np.round(credit_np * round_ticks / round_dur).astype(np.int64))
        # arbitrary reset pattern, like selection would apply
        reset = rng.random(n) < 0.3
        q_np = np.where(reset, 0.0, q_np + do_np)
        q_j = jnp.asarray(q_np, jnp.float32)


def test_credit_steps_ticks_adversarial():
    """The ROADMAP f32-clock caveat, fixed: at NON-representable step
    times (0.3, 0.7, 1/3, 3.3 ...) the integer-tick clock matches the
    f64 host reference EXACTLY at every one of 300 rounds — the old f32
    on-device clock could land floor() on the wrong side of an integer."""
    rng = np.random.default_rng(1)
    n, K, round_dur = 11, 5, 7.0
    step_time = rng.choice([0.3, 0.7, 1.5, 3.3, 1.0 / 3.0, 2.0, 16.0], n)
    step_ticks, round_ticks = sampler.time_ticks(step_time, round_dur)
    q = np.zeros(n)
    credit_f64 = np.zeros(n)
    credit_j = jnp.zeros((n,), jnp.int32)
    st_j = jnp.asarray(step_ticks)
    clock = jax.jit(functools.partial(sampler.credit_steps, K=K,
                                      round_ticks=round_ticks))
    for r in range(300):
        credit_f64 += round_dur
        avail = np.floor(credit_f64 / step_time)
        credit_f64 -= avail * step_time
        do_ref = np.minimum(avail, K - q)
        do_j, credit_j = clock(credit_j, st_j, jnp.asarray(q, jnp.float32))
        np.testing.assert_array_equal(np.asarray(do_j), do_ref,
                                      err_msg=f"round {r}")
        reset = rng.random(n) < 0.3
        q = np.where(reset, 0.0, q + do_ref)


def test_time_ticks_rational_scaling():
    """0.3 is read as the rational 3/10 and everything lands on one
    integer grid; un-tick-able times fail loudly instead of drifting."""
    st, rd = sampler.time_ticks(np.array([0.3, 2.0]), 7.0)
    assert rd == 70 and list(st) == [3, 20]
    st, rd = sampler.time_ticks(np.array([2.0, 16.0]), 7.0)
    assert rd == 7 and list(st) == [2, 16]
    with pytest.raises(ValueError, match="int32 ticks"):
        sampler.time_ticks(
            np.array([1.0 / 9999.0, 1.0 / 9998.0, 1.0 / 9997.0]), 7.0)
    # a step time below the tick resolution would quantize to ZERO ticks
    # (int division by zero in the jitted clock) — must fail loudly
    with pytest.raises(ValueError, match="zero ticks"):
        sampler.time_ticks(np.array([1e-5, 2.0]), 7.0)


def test_sample_selection_indices_uniform_without_replacement():
    idx, mask = jax.jit(sampler.sample_selection_indices,
                        static_argnums=(1, 2))(jax.random.PRNGKey(0), 10, 4)
    idx = np.asarray(idx)
    assert len(set(idx.tolist())) == 4
    assert float(mask.sum()) == 4.0
    np.testing.assert_array_equal(np.sort(np.where(np.asarray(mask) > 0)[0]),
                                  np.sort(idx))
    # all clients reachable over many draws (uniformity smoke)
    seen = set()
    for s in range(50):
        i, _ = sampler.sample_selection_indices(jax.random.PRNGKey(s), 10, 4)
        seen.update(np.asarray(i).tolist())
    assert seen == set(range(10))


def test_window_schedule_replicates_per_round_loop():
    from repro.core.fl_sim import _window_schedule
    rng = np.random.default_rng(1)
    for _ in range(20):
        total = float(rng.integers(1, 500))
        every = float(rng.integers(1, 200))
        dur = float(rng.integers(1, 20))
        ws = _window_schedule(total, every, dur)
        # reference: the original per-round loop's record points
        t, ne, rounds, recs = 0.0, 0.0, 0, []
        while t < total:
            if t >= ne:
                recs.append(rounds)
                ne += every
            rounds += 1
            t += dur
        assert sum(ws) == rounds
        # windows break exactly at the record points
        starts = np.cumsum([0] + ws[:-1]).tolist()
        assert starts == recs
