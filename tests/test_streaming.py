"""Streamed rounds + the host-offloaded cold tier (docs/architecture.md §13).

What is proven:

* **schedule parity** — the streamed schedule (single-sweep aggregation +
  churn-bounded scatter reset; unselected rows never rewritten) is
  BIT-EXACT against the two-sweep schedule for the dense engine
  (quant_bits {0,4} x quant_fused x {fp32, bf16}) and the paged engine
  (cold_bits {0,4}), states, metrics, cold-pool bytes and RNG key chain
  included. Bit-exactness is structural: the selection mask is an exact
  0/1 indicator of ``sample_selection_indices``' index set, so the fused
  blend ``m*s_new + (1-m)*x`` equals ``x`` off-selection and
  ``s_new.astype(dtype)`` on it — a scatter of the same values.
* **placement parity** — ``cold_placement="host"`` (LUQ cold pools in host
  numpy via ``HostColdPool``, rounds fed from a device-resident slab) is
  BIT-EXACT against device placement across cold_bits {0,4} x
  s_max {churn, ==n} on both data planes, sequential steps and supersteps,
  plus the forced-8-device mesh leg; checkpoints of host pools round-trip.
* **overlap correctness** — ``engine_run_stream`` (double-buffered
  :class:`~repro.core.streaming.PageStreamer`) equals sequential chunk
  dispatch exactly: the producer's writeback gate (chunk j waits on
  writebacks through j-2) plus the on-device ``_patch_slab`` read-after-
  write repair make prefetch invisible to the math. The streamer keeps the
  BatchPrefetcher contract: strict order, errors surface in stream
  position, hardened close.
* **write-traffic regression gate** — the compiled streamed round emits
  ZERO full (rows, D) client/init rewrites (``roofline.pass_through_copies``
  over the ENTRY root; two-sweep flags exactly its two blend fusions), and
  the fused round's "bytes accessed" drops >= 1.4x vs two-sweep at
  n=1024, D=2^20 (AOT-compiled, never executed) — the §13 acceptance gate.
* **tier accounting** — ``engine_resident_bytes_by_tier`` splits device vs
  host bytes: host pools never count against the device budget.
"""
import os
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import load_engine_checkpoint, save_engine_checkpoint
from repro.core import round_engine
from repro.core.round_engine import engine_resident_bytes, \
    engine_resident_bytes_by_tier
from repro.core.favas import FavasConfig, client_lambdas
from repro.core.streaming import HostColdPool, PageStreamer, engine_run_stream
from repro.data.device_corpus import make_classification_corpus
from repro.launch.mesh import make_model_mesh
from repro.launch.roofline import pass_through_copies, round_traffic_report

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# --------------------------------------------------------------------------
# helpers (the test_paged_engine fixtures, kept local: test modules here are
# self-contained by convention)
# --------------------------------------------------------------------------

def _params(dtype=jnp.float32):
    w = jnp.asarray(np.linspace(-1.0, 1.0, 48).reshape(8, 6), dtype)
    b = jnp.asarray(np.linspace(0.5, 1.5, 5), jnp.float32)
    return {"w": w, "b": b}


def _loss(p, batch):
    return sum(jnp.mean((l.astype(jnp.float32) - batch["t"]) ** 2)
               for l in jax.tree_util.tree_leaves(p))


def _batches(fcfg, T, seed=0):
    vals = np.linspace(0.0, 1.0, T * fcfg.n_clients * fcfg.R) + 0.01 * seed
    return {"t": jnp.asarray(vals.reshape(T, fcfg.n_clients, fcfg.R),
                             jnp.float32)}


def _engine(dtype, quant_bits=0, n=5, **kw):
    params = _params(dtype)
    fcfg = FavasConfig(n_clients=n, s_selected=2, local_steps=2, eta=0.1,
                       quant_bits=quant_bits)
    eng = round_engine.RoundEngine(
        params, fcfg, _loss, lambdas=jnp.asarray(client_lambdas(fcfg)), **kw)
    return eng, fcfg, params


def _assert_states_equal(a, b):
    for x, y in zip(a.server + a.clients + a.inits,
                    b.server + b.clients + b.inits):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    np.testing.assert_array_equal(np.asarray(a.counters), np.asarray(b.counters))
    np.testing.assert_array_equal(np.asarray(a.stale), np.asarray(b.stale))
    np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))
    assert int(a.t) == int(b.t)


def _assert_metrics_equal(ma, mb):
    assert set(ma) == set(mb)
    for k in ma:
        np.testing.assert_array_equal(np.asarray(ma[k]), np.asarray(mb[k]),
                                      err_msg=k)


def _cold_bytes(cold):
    """Raveled uint8 view of every cold leaf (bf16 via f32) for exact
    byte-level pool comparison across placements."""
    out = []
    for l in jax.tree_util.tree_leaves(cold):
        a = np.asarray(l, np.float32) if np.asarray(l).dtype.name == "bfloat16" \
            else np.asarray(l)
        out.append(a.ravel().view(np.uint8))
    return np.concatenate(out)


# --------------------------------------------------------------------------
# schedule parity: streamed (default) == two_sweep, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
@pytest.mark.parametrize("qb,qf", [(0, False), (4, False), (4, True)],
                         ids=["plain", "quant4", "quant4_fused"])
def test_dense_streamed_bit_exact_vs_two_sweep(dtype, qb, qf):
    T = 5
    e1, fcfg, params = _engine(dtype, quant_bits=qb, n=7, quant_fused=qf)
    e2, _, _ = _engine(dtype, quant_bits=qb, n=7, quant_fused=qf,
                       schedule="two_sweep")
    assert e1.schedule == "streamed"        # the default
    key = jax.random.PRNGKey(3)
    s1, m1 = e1.run(e1.init_state(params, key), _batches(fcfg, T))
    s2, m2 = e2.run(e2.init_state(params, key), _batches(fcfg, T))
    _assert_states_equal(s1, s2)
    _assert_metrics_equal(m1, m2)


@pytest.mark.parametrize("cold_bits", [0, 4])
def test_paged_streamed_bit_exact_vs_two_sweep(cold_bits):
    """s_max < n: real churn every round; hot stacks, cold-pool BYTES and
    the full metric set agree between the schedules."""
    T = 6
    e1, fcfg, params = _engine(jnp.float32, n=9, residency="paged", s_max=4,
                               cold_bits=cold_bits)
    e2, _, _ = _engine(jnp.float32, n=9, residency="paged", s_max=4,
                       cold_bits=cold_bits, schedule="two_sweep")
    key = jax.random.PRNGKey(7)
    s1, m1 = e1.run(e1.init_state(params, key), _batches(fcfg, T))
    s2, m2 = e2.run(e2.init_state(params, key), _batches(fcfg, T))
    _assert_states_equal(s1, s2)
    _assert_metrics_equal(m1, m2)
    np.testing.assert_array_equal(_cold_bytes(s1.cold), _cold_bytes(s2.cold))


def test_engine_round_rejects_unknown_schedule():
    e, fcfg, params = _engine(jnp.float32, n=5)
    state = e.init_state(params, jax.random.PRNGKey(0))
    batch = jax.tree_util.tree_map(lambda x: x[0], _batches(fcfg, 1))
    with pytest.raises(ValueError, match="schedule"):
        round_engine.engine_round(e.spec, state, batch, cfg=fcfg,
                                  loss_fn=_loss, lambdas=e.lambdas,
                                  schedule="zigzag")


# --------------------------------------------------------------------------
# placement parity: host cold tier == device cold tier, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("cold_bits", [0, 4])
@pytest.mark.parametrize("s_max", [4, 9], ids=["churn", "smax_eq_n"])
def test_host_placement_bit_exact_vs_device(cold_bits, s_max):
    T = 6
    ed, fcfg, params = _engine(jnp.float32, n=9, residency="paged",
                               s_max=s_max, cold_bits=cold_bits)
    eh, _, _ = _engine(jnp.float32, n=9, residency="paged", s_max=s_max,
                       cold_bits=cold_bits, cold_placement="host")
    key = jax.random.PRNGKey(11)
    sd = ed.init_state(params, key)
    sh = eh.init_state(params, key)
    assert isinstance(sh.cold, HostColdPool)
    # tier accounting: host pools never count against the device budget
    bt_h, bt_d = engine_resident_bytes_by_tier(sh), \
        engine_resident_bytes_by_tier(sd)
    assert bt_h["host"] > 0 and bt_d["host"] == 0
    assert engine_resident_bytes(sh) == bt_h["device"]
    assert bt_h["device"] + bt_h["host"] == bt_d["device"] + bt_d["host"]
    assert bt_h["device"] < bt_d["device"]
    batches = _batches(fcfg, T)
    sd, md = ed.run(sd, batches)
    sh, mh = eh.run(sh, batches)
    _assert_states_equal(sd, sh)
    _assert_metrics_equal(md, mh)
    np.testing.assert_array_equal(_cold_bytes(sd.cold), _cold_bytes(sh.cold))


def test_host_placement_sequential_steps():
    T = 5
    ed, fcfg, params = _engine(jnp.float32, n=9, residency="paged", s_max=4,
                               cold_bits=4)
    eh, _, _ = _engine(jnp.float32, n=9, residency="paged", s_max=4,
                       cold_bits=4, cold_placement="host")
    key = jax.random.PRNGKey(13)
    sd = ed.init_state(params, key)
    sh = eh.init_state(params, key)
    batches = _batches(fcfg, T)
    for t in range(T):
        b = jax.tree_util.tree_map(lambda x: x[t], batches)
        sd, md = ed.step(sd, b)
        sh, mh = eh.step(sh, b)
        _assert_metrics_equal(md, mh)
    _assert_states_equal(sd, sh)


def test_host_placement_requires_paged():
    with pytest.raises(ValueError, match="host"):
        _engine(jnp.float32, n=5, cold_placement="host")


def test_checkpoint_roundtrip_host_pool(tmp_path):
    """Host pools ride the pytree protocol through save/load; the restored
    state is bit-equal AND continues bit-exactly."""
    eh, fcfg, params = _engine(jnp.float32, n=9, residency="paged", s_max=4,
                               cold_bits=4, cold_placement="host")
    sh = eh.init_state(params, jax.random.PRNGKey(5))
    sh, _ = eh.run(sh, _batches(fcfg, 4))
    p = save_engine_checkpoint(str(tmp_path), 4, sh)
    eh2, _, _ = _engine(jnp.float32, n=9, residency="paged", s_max=4,
                        cold_bits=4, cold_placement="host")
    tmpl = eh2.init_state(params, jax.random.PRNGKey(0))
    restored = load_engine_checkpoint(p, tmpl)
    _assert_states_equal(sh, restored)
    assert isinstance(restored.cold, HostColdPool)
    np.testing.assert_array_equal(_cold_bytes(sh.cold),
                                  _cold_bytes(restored.cold))
    sh2, _ = eh.run(sh, _batches(fcfg, 3, seed=1))
    sh3, _ = eh2.run(restored, _batches(fcfg, 3, seed=1))
    _assert_states_equal(sh2, sh3)


# --------------------------------------------------------------------------
# the page streamer: overlap == sequential, on both data planes
# --------------------------------------------------------------------------

def test_run_stream_matches_sequential_chunks():
    n_chunks, T = 4, 3
    e1, fcfg, params = _engine(jnp.float32, n=9, residency="paged", s_max=4,
                               cold_bits=4, cold_placement="host")
    e2, _, _ = _engine(jnp.float32, n=9, residency="paged", s_max=4,
                       cold_bits=4, cold_placement="host")
    key = jax.random.PRNGKey(17)
    s1 = e1.init_state(params, key)
    s2 = e2.init_state(params, key)
    chunk_batches = [_batches(fcfg, T, seed=i) for i in range(n_chunks)]
    s1, m1 = engine_run_stream(e1, s1, n_chunks=n_chunks, chunk_rounds=T,
                               chunk_batches=chunk_batches)
    ms = []
    for cb in chunk_batches:
        s2, m = e2.run(s2, cb)
        ms.append(m)
    _assert_states_equal(s1, s2)
    np.testing.assert_array_equal(_cold_bytes(s1.cold), _cold_bytes(s2.cold))
    m2 = jax.tree_util.tree_map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs]), *ms)
    _assert_metrics_equal(m1, m2)


def test_run_stream_zero_churn_smax_eq_n():
    """s_max == n: every chunk's churn plan is empty, the slab is the
    all-dummy row, and the streamer still matches a device-placed engine
    (dense-passthrough leg of the §13 matrix)."""
    e1, fcfg, params = _engine(jnp.float32, n=7, residency="paged",
                               cold_placement="host")       # s_max -> n
    key = jax.random.PRNGKey(9)
    s1 = e1.init_state(params, key)
    cbs = [_batches(fcfg, 2, seed=i) for i in range(3)]
    s1, _ = engine_run_stream(e1, s1, n_chunks=3, chunk_rounds=2,
                              chunk_batches=cbs)
    e2, _, _ = _engine(jnp.float32, n=7, residency="paged")
    s2 = e2.init_state(params, key)
    for cb in cbs:
        s2, _ = e2.run(s2, cb)
    _assert_states_equal(s1, s2)


def _corpus(n):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 6)).astype(np.float32)
    y = rng.integers(0, 3, size=(64,)).astype(np.int64)
    parts = [np.arange(i, 64, n) for i in range(n)]
    return make_classification_corpus(x, y, parts, batch=2)


def _corpus_loss(p, batch):
    out = batch["x"] @ p["w"][:6, :5].astype(jnp.float32)
    return jnp.mean((out - batch["y"][:, None]) ** 2)


def test_run_stream_device_plane():
    """Device data plane: host placement == device placement under
    ``run_device``, and ``engine_run_stream(corpus=...)`` == sequential
    ``run_device`` chunks."""
    n, T = 9, 6
    params = _params()
    fcfg = FavasConfig(n_clients=n, s_selected=2, local_steps=2, eta=0.1)
    corpus = _corpus(n)

    def mk(**kw):
        return round_engine.RoundEngine(
            params, fcfg, _corpus_loss,
            lambdas=jnp.asarray(client_lambdas(fcfg)),
            residency="paged", s_max=4, cold_bits=4, **kw)

    ed, eh = mk(), mk(cold_placement="host")
    key = jax.random.PRNGKey(3)
    sd = ed.init_state(params, key)
    sh = eh.init_state(params, key)
    sd, md = ed.run_device(sd, corpus, T)
    sh, mh = eh.run_device(sh, corpus, T)
    _assert_states_equal(sd, sh)
    _assert_metrics_equal(md, mh)

    e3, e4 = mk(cold_placement="host"), mk(cold_placement="host")
    s3 = e3.init_state(params, key)
    s4 = e4.init_state(params, key)
    s3, _ = engine_run_stream(e3, s3, n_chunks=3, chunk_rounds=2,
                              corpus=corpus)
    for _ in range(3):
        s4, _ = e4.run_device(s4, corpus, 2)
    _assert_states_equal(s3, s4)


def test_run_stream_validates_planes():
    eh, fcfg, params = _engine(jnp.float32, n=9, residency="paged", s_max=4,
                               cold_placement="host")
    sh = eh.init_state(params, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="exactly one"):
        engine_run_stream(eh, sh, n_chunks=2, chunk_rounds=2)
    ed, _, _ = _engine(jnp.float32, n=9, residency="paged", s_max=4)
    sd = ed.init_state(params, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="host"):
        engine_run_stream(ed, sd, n_chunks=2, chunk_rounds=2,
                          chunk_batches=[_batches(fcfg, 2)] * 2)


# --------------------------------------------------------------------------
# PageStreamer contract (the BatchPrefetcher contract + the writeback gate)
# --------------------------------------------------------------------------

def test_page_streamer_strict_order():
    """Chunks arrive in index order; the consumer acknowledges each chunk
    with mark_written (the gate contract — a consumer that never writes
    back would starve the producer at chunk 2, by design)."""
    with PageStreamer(lambda i: i * i, n_chunks=5, depth=2) as ps:
        out = []
        for i, v in enumerate(ps):
            out.append(v)
            ps.mark_written(i)
        assert out == [0, 1, 4, 9, 16]


def test_page_streamer_error_in_stream_position():
    """Chunk 2 raises in the producer: chunks 0 and 1 still arrive, the
    error surfaces exactly at get() #2, and close() stays clean."""
    def make(i):
        if i == 2:
            raise RuntimeError("boom at 2")
        return i

    with PageStreamer(make, n_chunks=5, depth=2) as ps:
        assert ps.get() == 0
        ps.mark_written(0)
        assert ps.get() == 1
        ps.mark_written(1)
        with pytest.raises(RuntimeError, match="boom at 2"):
            ps.get()


def test_page_streamer_close_unblocks_producer():
    """close() while the producer is parked on the writeback gate must not
    hang: the gate polls the stop flag (hardened-close contract)."""
    started = threading.Event()

    def make(i):
        started.set()
        return i

    ps = PageStreamer(make, n_chunks=10, depth=2)
    assert started.wait(5.0)
    assert ps.get() == 0
    t0 = time.monotonic()
    ps.close(timeout=10.0)
    assert time.monotonic() - t0 < 10.0


def test_page_streamer_writeback_gate():
    """The producer may run at most 2 chunks ahead of the consumer's
    writebacks: chunk j is not MADE until mark_written(j-2) — the overlap-
    correctness invariant (the slab for chunk j is gathered from pool
    state that already includes chunk j-2's writeback; the j-1 overlap is
    repaired on device by _patch_slab)."""
    made = []
    lock = threading.Lock()

    def make(i):
        with lock:
            made.append(i)
        return i

    with PageStreamer(make, n_chunks=6, depth=4) as ps:
        # without any writebacks the producer may build chunks 0 and 1
        # (gate: wb >= i - 2 with wb starting at -1) but never chunk 2
        assert ps.get() == 0
        assert ps.get() == 1
        time.sleep(0.4)
        with lock:
            assert made == [0, 1], made
        ps.mark_written(0)
        time.sleep(0.4)
        with lock:
            assert made == [0, 1, 2], made
        for i in range(1, 5):
            ps.mark_written(i)
        assert [ps.get() for _ in range(4)] == [2, 3, 4, 5]


# --------------------------------------------------------------------------
# write-traffic regression gates (roofline audits, §13 acceptance)
# --------------------------------------------------------------------------

def test_streamed_round_no_pass_through_rewrites():
    """The compiled streamed round's entry outputs contain ZERO full
    (n, D) client/init rewrites — every touched output is an in-place
    scatter/DUS on the donated buffer. The two-sweep round flags exactly
    its two blend fusions (clients + inits), which is what the streamed
    schedule deleted."""
    n = 64
    w = jnp.asarray(np.linspace(-1, 1, 48 * 40).reshape(48, 40), jnp.float32)
    params = {"w": w}
    fcfg = FavasConfig(n_clients=n, s_selected=4, local_steps=2, eta=0.1)
    batch = {"t": jnp.zeros((n, fcfg.R), jnp.float32)}

    def compiled(schedule):
        eng = round_engine.RoundEngine(
            params, fcfg, _loss, lambdas=jnp.asarray(client_lambdas(fcfg)),
            schedule=schedule)
        st = eng.init_state(params, jax.random.PRNGKey(0))
        return eng._round.lower(st, batch).compile()

    flagged = pass_through_copies(compiled("two_sweep").as_text(),
                                  rows=n, min_cols=1024)
    assert len(flagged) == 2, flagged          # clients + inits full blends
    assert pass_through_copies(compiled("streamed").as_text(),
                               rows=n, min_cols=1024) == []


def test_fused_round_traffic_reduction():
    """HBM bytes-accessed audit at the §13 acceptance shape (n=1024,
    D=2^20, AOT-compiled only — never executed): the streamed fused round
    moves >= 1.4x fewer client-buffer bytes than two-sweep (~2R+2W ->
    ~1R+1W per resident byte) and emits no pass-through rewrite."""
    from repro.kernels.ops import favas_fused_flat, favas_stream_flat
    n, D, s = 1024, 2 ** 20, 4
    srv = jax.ShapeDtypeStruct((D,), jnp.float32)
    stack = jax.ShapeDtypeStruct((n, D), jnp.float32)
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    idx = jax.ShapeDtypeStruct((s,), jnp.int32)

    def two_sweep(server, clients, inits, alpha, mask):
        return favas_fused_flat(server, clients, inits, alpha, mask, s,
                                use_kernel=False)

    def streamed(server, clients, inits, alpha, mask, sel_idx):
        s_new = favas_stream_flat(server, clients, inits, alpha, mask, s,
                                  use_kernel=False)
        return (s_new, clients.at[sel_idx].set(s_new.astype(clients.dtype)),
                inits.at[sel_idx].set(s_new.astype(inits.dtype)))

    c_two = jax.jit(two_sweep, donate_argnums=(1, 2)).lower(
        srv, stack, stack, vec, vec).compile()
    c_str = jax.jit(streamed, donate_argnums=(1, 2)).lower(
        srv, stack, stack, vec, vec, idx).compile()
    r_two = round_traffic_report(c_two, rows=n, min_cols=1024)
    r_str = round_traffic_report(c_str, rows=n, min_cols=1024)
    assert r_str["pass_through_copies"] == []
    assert len(r_two["pass_through_copies"]) == 2
    ratio = r_two["bytes_accessed"] / r_str["bytes_accessed"]
    assert ratio >= 1.4, (r_two["bytes_accessed"], r_str["bytes_accessed"])


# --------------------------------------------------------------------------
# forced-8-device mesh leg (the CI ``streaming`` job runs under the flag;
# the slow subprocess self-run covers plain environments)
# --------------------------------------------------------------------------

def _mesh_params():
    def f(*shape, seed=0):
        size = int(np.prod(shape))
        v = np.linspace(-1.0, 1.0, size).reshape(shape) * (1.0 + 0.1 * seed)
        return jnp.asarray(v, jnp.float32)
    return {"embed": {"table": f(16, 6, seed=1)},
            "blk": {"wq": {"w": f(6, 16, seed=2), "b": f(16, seed=3)}},
            "mlp": {"down": {"w": f(16, 5, seed=6)}}}


@needs8
@pytest.mark.parametrize("cold_bits", [0, 4])
def test_mesh_host_placement_bit_exact(cold_bits):
    """8-device mesh: host cold placement == device placement (the slab is
    device_put with the cold codec's per-bucket shardings), and the
    streamer matches sequential chunks on the mesh."""
    mesh = make_model_mesh(8)
    n, T = 9, 6
    params = _mesh_params()
    fcfg = FavasConfig(n_clients=n, s_selected=2, local_steps=2, eta=0.1)

    def mk(**kw):
        return round_engine.RoundEngine(
            params, fcfg, _loss, lambdas=jnp.asarray(client_lambdas(fcfg)),
            mesh=mesh, residency="paged", s_max=4, cold_bits=cold_bits, **kw)

    ed, eh = mk(), mk(cold_placement="host")
    key = jax.random.PRNGKey(3)
    sd = ed.init_state(params, key)
    sh = eh.init_state(params, key)
    assert isinstance(sh.cold, HostColdPool)
    batches = _batches(fcfg, T)
    sd, md = ed.run(sd, batches)
    sh, mh = eh.run(sh, batches)
    _assert_states_equal(sd, sh)
    _assert_metrics_equal(md, mh)
    np.testing.assert_array_equal(_cold_bytes(sd.cold), _cold_bytes(sh.cold))

    e1, e2 = mk(cold_placement="host"), mk(cold_placement="host")
    s1 = e1.init_state(params, key)
    s2 = e2.init_state(params, key)
    cbs = [_batches(fcfg, 2, seed=i) for i in range(3)]
    s1, _ = engine_run_stream(e1, s1, n_chunks=3, chunk_rounds=2,
                              chunk_batches=cbs)
    for cb in cbs:
        s2, _ = e2.run(s2, cb)
    _assert_states_equal(s1, s2)
    np.testing.assert_array_equal(_cold_bytes(s1.cold), _cold_bytes(s2.cold))


@pytest.mark.slow
def test_streaming_subprocess_8dev():
    """Self-run this file under the forced-8-device flag so plain
    environments still exercise the mesh leg (the CI ``streaming`` job
    runs the same command directly)."""
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-m", "not slow",
         "tests/test_streaming.py", "-k", "mesh"],
        capture_output=True, text=True, env=env, cwd=root, timeout=1200)
    assert out.returncode == 0, out.stdout + out.stderr
