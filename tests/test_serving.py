"""Continuous-batching scheduler + slot-wise decode engine tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models.model import init_params, forward
from repro.launch.steps import serve_config
from repro.serving import Request, ContinuousBatcher
from repro.serving.engine import DecodeEngine


def test_scheduler_logic_with_dummy_engine():
    """Echo engine: next token = (input + 1) mod V. Checks admission, slot
    reuse, prompt prefill, EOS and max-token termination."""
    V = 50

    def step_fn(tokens, pos):
        nxt = (np.asarray(tokens)[:, 0] + 1) % V
        logits = np.full((tokens.shape[0], 1, V), -1e9, np.float32)
        for i, t in enumerate(nxt):
            logits[i, 0, t] = 0.0
        return jnp.asarray(logits)

    sched = ContinuousBatcher(batch_slots=2, step_fn=step_fn, vocab_raw=V)
    # 5 requests through 2 slots
    for uid in range(5):
        sched.submit(Request(uid=uid, prompt=[uid, uid + 1],
                             max_new_tokens=3))
    sched.submit(Request(uid=99, prompt=[7], max_new_tokens=10, eos_id=9))
    done = sched.run()
    assert set(done) == {0, 1, 2, 3, 4, 99}
    for uid in range(5):
        # echo chain: last prompt token uid+1 -> uid+2, uid+3, uid+4
        assert done[uid].output == [uid + 2, uid + 3, uid + 4]
    assert done[99].output == [8, 9]          # stops at eos_id=9
    assert all(not s.live for s in sched.slots)


def test_engine_matches_forward():
    """Slot-wise engine with staggered admission reproduces teacher-forced
    forward logits for each request."""
    cfg = serve_config(get_reduced_config("qwen3-4b"))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    engine = DecodeEngine(params, cfg, batch_slots=2, max_seq=32,
                          cache_dtype=jnp.float32)
    sched = ContinuousBatcher(2, engine.step_fn, vocab_raw=cfg.vocab_size_raw)
    prompts = [[5, 9, 2, 7], [11, 3], [8, 8, 8]]
    for uid, pr in enumerate(prompts):
        sched.submit(Request(uid=uid, prompt=pr, max_new_tokens=4))
    done = sched.run()
    assert set(done) == {0, 1, 2}
    # greedy continuation must match a teacher-forced forward pass
    for uid, pr in enumerate(prompts):
        seq = list(pr) + done[uid].output
        logits, _ = forward(params, cfg, {"tokens": jnp.asarray([seq])})
        for t in range(len(pr) - 1, len(seq) - 1):
            pred = int(jnp.argmax(logits[0, t, :cfg.vocab_size_raw]))
            assert pred == seq[t + 1], (uid, t)


def test_engine_slot_reuse_no_leakage():
    """A slot reused by a new request must not see the old cache rows."""
    cfg = serve_config(get_reduced_config("llama3-8b"))
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    engine = DecodeEngine(params, cfg, batch_slots=1, max_seq=16,
                          cache_dtype=jnp.float32)
    sched = ContinuousBatcher(1, engine.step_fn, vocab_raw=cfg.vocab_size_raw)
    sched.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    sched.submit(Request(uid=1, prompt=[4, 5], max_new_tokens=2))
    done = sched.run()
    # request 1 decoded alone must equal request 1 decoded after reuse
    engine2 = DecodeEngine(params, cfg, batch_slots=1, max_seq=16,
                           cache_dtype=jnp.float32)
    sched2 = ContinuousBatcher(1, engine2.step_fn, vocab_raw=cfg.vocab_size_raw)
    sched2.submit(Request(uid=1, prompt=[4, 5], max_new_tokens=2))
    done2 = sched2.run()
    assert done[1].output == done2[1].output
